"""Derived backward passes (ISSUE 6): each backward recurrence kind is
bit-identical to its mirrored jnp oracle on integer inputs in interpret
mode, and a full train step's jaxpr contains no oracle recompute."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import analysis
from repro.configs import get_config
from repro.core import hardware as hw
from repro.kernels import flash_attention as fa
from repro.kernels import ops, ref
from repro.train import train_step as ts

HW = hw.get_entry("tpu_v5e")
MASKS = [(False, 0, 0), (True, 0, 0), (True, 8, 0), (True, 8, 4)]


def _ints(rng, *shape):
    return jnp.asarray(rng.integers(-2, 3, shape).astype(np.float32))


def _flash_setup(rng, causal, window, prefix, bq=16, bk=16):
    b, hkv, g, sq, sk, hd, vd = 1, 2, 2, 24, 40, 8, 8
    scale = 0.5
    q = _ints(rng, b, sq, hkv, g, hd)
    k = _ints(rng, b, sk, hkv, hd)
    v = _ints(rng, b, sk, hkv, vd)
    do = _ints(rng, b, sq, hkv, g, vd)
    fwd = fa._stats_executor(b, hkv, g, sq, sk, hd, vd, "float32", "float32",
                             HW.name, True, causal, scale, (bq, bk), window,
                             prefix)
    out5, m, l = fwd(q, k, v)
    do5 = do.transpose(0, 2, 3, 1, 4)
    delta = jnp.sum(do5.astype(jnp.float32) * out5.astype(jnp.float32),
                    axis=-1)
    sqp, skp = -(-sq // bq) * bq, -(-sk // bk) * bk
    pad5 = lambda a, t: jnp.pad(a, ((0, 0), (0, t - a.shape[1])) +
                                ((0, 0),) * (a.ndim - 2))
    padded = (pad5(q, sqp), pad5(k, skp), pad5(v, skp), pad5(do, sqp),
              jnp.pad(delta, ((0, 0), (0, 0), (0, 0), (0, sqp - sq))))
    dims = (b, hkv, g, sq, sk, hd, vd)
    return dims, scale, (q, k, v, do, m, l, delta), padded


@pytest.mark.parametrize("causal,window,prefix", MASKS)
def test_flash_dq_bit_identical_to_ref(causal, window, prefix):
    """The ``flash_dq`` kind (streamed keys, carried dq, saved (m, l)
    statistics) against the blocked jnp mirror — exact equality: both walk
    the key blocks in the same order with the same f32 ops."""
    rng = np.random.default_rng(0)
    bq = bk = 16
    (b, hkv, g, sq, sk, hd, vd), scale, (q, k, v, do, m, l, delta), \
        (qp, kp, vp, dop, dp) = _flash_setup(rng, causal, window, prefix)
    fn = fa._dq_executor(b, hkv, g, sq, sk, hd, vd, "float32", HW.name,
                         True, causal, scale, (bq, bk), window, prefix)
    dq_k = fn(q, k, k, do, v, m, l, delta)
    dq_r = ref.flash_dq_ref(qp, kp, vp, dop, m, l, dp, scale=scale,
                            causal=causal, bq=bq, bk=bk, window=window,
                            prefix_len=prefix, logical_k=sk)
    np.testing.assert_array_equal(np.asarray(dq_k),
                                  np.asarray(dq_r[:, :, :, :sq]))


@pytest.mark.parametrize("causal,window,prefix", MASKS)
def test_flash_dkv_bit_identical_to_ref(causal, window, prefix):
    """The ``flash_dkv`` kind (the transposed weld: key rows, streamed
    queries, carried dk + exported dv) against the blocked jnp mirror —
    including the always-on padded-query mask that keeps the degenerate
    padded-row statistics from contaminating real key gradients."""
    rng = np.random.default_rng(1)
    bq = bk = 16
    (b, hkv, g, sq, sk, hd, vd), scale, (q, k, v, do, m, l, delta), \
        (qp, kp, vp, dop, dp) = _flash_setup(rng, causal, window, prefix)
    fn = fa._dkv_executor(b, hkv, g, sq, sk, hd, vd, "float32", HW.name,
                          True, causal, scale, (bk, bq), window, prefix)
    dk_k, dv_k = fn(k, q, q, do, v, m, l, delta)
    dk_r, dv_r = ref.flash_dkv_ref(qp, kp, vp, dop, m, l, dp, scale=scale,
                                   causal=causal, bj=bk, bi=bq,
                                   window=window, prefix_len=prefix,
                                   logical_q=sq)
    np.testing.assert_array_equal(np.asarray(dk_k),
                                  np.asarray(dk_r[:, :, :, :sk]))
    np.testing.assert_array_equal(np.asarray(dv_k), np.asarray(dv_r))


def test_ssd_backward_bit_identical_to_ref():
    """The ``ssd_backward`` kind (reverse-streamed chunks, carried dh,
    forward factoring replayed from the saved entering states) against the
    lax.scan mirror — exact equality on integer inputs."""
    rng = np.random.default_rng(2)
    b, s, h, p, n, chunk = 2, 14, 2, 4, 4, 4
    nc = -(-s // chunk)
    sp = nc * chunk
    xi = _ints(rng, b, s, h, p)
    di = -jnp.abs(_ints(rng, b, s, h))
    Bi = _ints(rng, b, s, n)
    Ci = _ints(rng, b, s, n)
    gy = _ints(rng, b, s, h, p)
    gf = _ints(rng, b, h, p, n)
    h0 = _ints(rng, b, h, p, n)
    _, resid = ops._ssd_kernel_fwd(xi, di, Bi, Ci, h0, chunk, HW.name, True)
    hin = resid[4]
    np.testing.assert_array_equal(np.asarray(hin[:, 0]), np.asarray(h0))

    def prep(a):
        a = jnp.pad(a, ((0, 0), (0, sp - s)) + ((0, 0),) * (a.ndim - 2))
        return jnp.flip(a.reshape(b, nc, chunk, *a.shape[2:]), axis=1)

    args = (prep(Ci), prep(Bi), prep(gy), prep(xi), prep(di),
            jnp.flip(hin, axis=1), gf)
    fn = ops._ssd_bwd_executor(b, nc, chunk, h, p, n, "float32", HW.name,
                               True)
    for a, bb, name in zip(fn(*args), ref.ssd_bwd_ref(*args),
                           ["dX", "dh0", "dB", "dC", "ddA"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb),
                                      err_msg=name)


def test_gated_backward_bit_identical_to_ref():
    """The degenerate ``gated_backward`` kind — the cotangent recurrence
    run through the forward kernel body on flipped, gate-shifted operands —
    against the chunked associative-scan mirror."""
    rng = np.random.default_rng(3)
    b, s, w, chunk = 2, 16, 8, 4
    nc = s // chunk
    la = -jnp.abs(_ints(rng, b, s, w))
    dy = _ints(rng, b, s, w)
    la_shift = jnp.concatenate([la[:, 1:], jnp.zeros((b, 1, w), jnp.float32)],
                               axis=1)
    laf = jnp.flip(la_shift, axis=1)
    dyf = jnp.flip(dy, axis=1)
    z = jnp.zeros((b, w), jnp.float32)
    fn = ops._gated_bwd_executor(b, nc, chunk, w, HW.name, True)
    hk, fk = fn(laf.reshape(b, nc, chunk, w), dyf.reshape(b, nc, chunk, w), z)
    # bit-identity: the backward derivation must reproduce the proven
    # forward kernel exactly (same monoid, its own schedule-cache entry)
    fwd = ops._gated_executor(b, nc, chunk, w, "float32", HW.name, True)
    hf, ff = fwd(laf.reshape(b, nc, chunk, w), dyf.reshape(b, nc, chunk, w),
                 z)
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hf))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(ff))
    # semantics vs the chunked jnp mirror — XLA's FMA fusion differs
    # between the pallas body and the open-coded scan, so 1-ulp tolerance
    hr, fr = ref.gated_chunk_ref(laf, dyf, z, chunk)
    np.testing.assert_allclose(np.asarray(hk).reshape(b, s, w),
                               np.asarray(hr), rtol=0, atol=5e-7)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(fr), rtol=0,
                               atol=5e-7)


@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-780m"])
def test_train_step_jaxpr_has_no_oracle_recompute(arch, monkeypatch):
    """Acceptance pin: tracing a full train step (forward + backward +
    update) on a kernel-dispatch entry never reaches a jnp oracle — every
    custom-VJP backward is a derived kernel.  The oracles are stubbed to
    raise, so any recompute path fails the trace loudly."""
    def boom(name):
        def f(*a, **k):
            raise AssertionError(f"oracle recompute reached: {name}")
        return f

    monkeypatch.setattr(ops, "_oracle_attention", boom("attention"))
    monkeypatch.setattr(ops, "_ssd_oracle", boom("ssd"))
    monkeypatch.setattr(ops, "_gated_oracle", boom("gated"))
    monkeypatch.setattr(ref, "eval_expr", boom("eval_expr"))
    cfg = get_config(arch, reduced=True).with_(attn_impl="pallas")
    with hw.use_hardware("cpu"):
        jaxpr = ts.trace_step_jaxpr(cfg, batch_size=2, seq=32)
    assert not analysis.lint_jaxpr(jaxpr, rules=("no-oracle-recompute",))
