"""Property-test shim: real hypothesis when installed, a deterministic
fallback driver otherwise.

The tier-1 environment does not ship ``hypothesis``; a bare import killed the
whole suite at collection.  Instead of skipping every property test, this
module re-implements the tiny strategy surface the suite uses (``integers``,
``lists``, ``sampled_from``, ``data``, ``.map``) and runs each ``@given``
test over a fixed-seed sample of draws — so the properties still execute
everywhere, and upgrade to full shrinking hypothesis wherever it exists.
"""
from __future__ import annotations

import functools
import random

try:                                          # pragma: no cover - env specific
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _Data:
        """Stand-in for hypothesis' interactive ``data()`` object."""
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[rng.randrange(len(options))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def data():
            return _Strategy(lambda rng: _Data(rng))

    _FALLBACK_MAX_EXAMPLES = 10

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            target = getattr(fn, "__wrapped__", fn)
            n = min(getattr(fn, "_compat_max_examples",
                            _FALLBACK_MAX_EXAMPLES), _FALLBACK_MAX_EXAMPLES)

            @functools.wraps(target)
            def runner():
                for example in range(n):
                    rng = random.Random((example + 1) * 0x9E3779B1)
                    args = [s.draw(rng) for s in strategies]
                    try:
                        fn(*args)
                    except Exception:
                        print(f"falsifying example (fallback driver): "
                              f"{fn.__name__}{tuple(args)}")
                        raise

            # pytest must not try to fixture-inject the strategy params
            runner.__signature__ = __import__("inspect").Signature()
            return runner
        return deco
