"""The static block-size solver must reproduce the paper's derivation."""
import dataclasses

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import blocking
from repro.core.lifting import TPU_V5E, V100


def test_paper_v100_block_is_32():
    """§3.4: 3 blocks of 32x32 doubles = 24 KiB <= 32 KiB L1 per SM."""
    assert blocking.solve_blocks_square(V100, "float64", n_arrays=3) == 32


def test_paper_v100_shared_memory_block_is_64():
    """§3.4: with shared-memory L1 aggregation (128 KiB) the optimum doubles."""
    shared = dataclasses.replace(
        V100, vmem=dataclasses.replace(V100.vmem, capacity_bytes=128 * 2**10))
    assert blocking.solve_blocks_square(shared, "float64", n_arrays=3) == 64


def test_block_working_set_fits_budget():
    bc = blocking.solve_blocks(4096, 4096, 4096, "bfloat16", TPU_V5E,
                               vmem_budget_frac=0.5)
    assert bc.vmem_bytes <= TPU_V5E.vmem.capacity_bytes * 0.5


def test_blocks_are_mxu_aligned():
    bc = blocking.solve_blocks(4096, 4096, 4096, "bfloat16", TPU_V5E)
    assert bc.bm % 128 == 0 and bc.bn % 128 == 0
    assert bc.bk % 16 == 0          # bf16 sublane packing


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["bfloat16", "float32"]),
       st.sampled_from([(512, 512, 512), (4096, 1024, 2048), (128, 8192, 128)]))
def test_solver_feasible_across_shapes(dtype, mkn):
    m, k, n = mkn
    bc = blocking.solve_blocks(m, k, n, dtype)
    assert bc.bm >= 128 and bc.bn >= 128 and bc.bk >= 1
    assert bc.arithmetic_intensity > 0


def test_bigger_budget_never_lowers_intensity():
    a = blocking.solve_blocks(8192, 8192, 8192, "bfloat16",
                              vmem_budget_frac=0.25)
    b = blocking.solve_blocks(8192, 8192, 8192, "bfloat16",
                              vmem_budget_frac=0.5)
    assert b.arithmetic_intensity >= a.arithmetic_intensity


def test_grid_covers_problem():
    bc = blocking.solve_blocks(1000, 700, 900, "float32")
    gm, gn, gk = blocking.grid_for(1000, 700, 900, bc)
    assert gm * bc.bm >= 1000 and gn * bc.bn >= 900 and gk * bc.bk >= 700


def test_materialized_combine_shrinks_tiles():
    """General semirings materialize a (bm, bn, bk) f32 pairing intermediate
    in-block; with that term in the working-set model the solver must pick a
    strictly smaller tile volume than the MXU GEMM objective, and the
    intermediate alone must fit the budget."""
    budget = int(TPU_V5E.vmem.capacity_bytes * 0.25)
    gemm = blocking.solve_blocks(2048, 2048, 2048, "float32", TPU_V5E,
                                 vmem_budget_frac=0.25)
    trop = blocking.solve_blocks(2048, 2048, 2048, "float32", TPU_V5E,
                                 vmem_budget_frac=0.25,
                                 materialized_combine=True)
    assert trop.bm * trop.bn * trop.bk * 4 <= budget
    assert trop.bm * trop.bn * trop.bk < gemm.bm * gemm.bn * gemm.bk
    assert trop.vmem_bytes <= budget
    # the reported working set includes the intermediate
    assert trop.vmem_bytes >= trop.bm * trop.bn * trop.bk * 4
