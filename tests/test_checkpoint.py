"""Checkpointing: atomicity, integrity fallback, async, keep-k, resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def tree():
    return {"a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                  "b16": jnp.ones((4,), jnp.bfloat16) * 1.5},
            "step_arr": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(10, t, metadata={"data_step": 10})
    restored, manifest = ck.restore(t)
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype          # bf16 preserved


def test_keep_k_garbage_collection(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, tree())
    assert ck.all_steps() == [3, 4]


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(5, tree())
    ck.wait()
    assert ck.all_steps() == [5]


def test_corruption_falls_back_to_previous(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    t = tree()
    ck.save(1, t)
    ck.save(2, jax.tree.map(lambda x: x + 1 if x.dtype != jnp.bfloat16 else x, t))
    # corrupt the latest npz
    npz = os.path.join(str(tmp_path), "step_0000000002", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(30)
        f.write(b"\xde\xad")
    restored, manifest = ck.restore(t)
    assert manifest["step"] == 1            # fell back
    np.testing.assert_array_equal(np.asarray(restored["a"]["w"]),
                                  np.asarray(t["a"]["w"]))


def test_atomic_partial_write_invisible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    # simulate a crash mid-write: tmp dir left behind
    os.makedirs(os.path.join(str(tmp_path), "step_0000000009.tmp-partial"))
    assert ck.all_steps() == [1]


def test_restore_with_shardings(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(3, t)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
    restored, _ = ck.restore(t, shardings=sh)
    assert restored["a"]["w"].sharding == NamedSharding(mesh, P())


def test_missing_dir_raises(tmp_path):
    ck = Checkpointer(str(tmp_path / "empty"))
    with pytest.raises(FileNotFoundError):
        ck.restore(tree())
