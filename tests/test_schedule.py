"""The schedule-derivation subsystem: lifted ONF -> Schedule -> Pallas.

Covers the satellite checklist: gamma round-trips, gamma_blocked vs
lift_loop access-rewrite consistency, and the keystone — the emitted kernel
for a derived schedule matching both the ``onf_gemm`` ONF oracle and
``jnp.dot`` in interpret mode, including non-divisible (padded/masked)
shapes — plus the schedule cache counters and the hardware registry.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import expr
from repro.core import hardware as hw
from repro.core import moa, onf
from repro.core import schedule as sched
from repro.core.blocking import BlockChoice
from repro.kernels import ops
from repro.kernels.emit import emit_pallas


def _err(got, want):
    return float(np.max(np.abs(np.asarray(got, np.float32)
                               - np.asarray(want, np.float32))))


# ---------------------------------------------------------------------------
# property round-trips (plain pytest, no hypothesis dependency)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1,), (7,), (3, 5), (2, 3, 4), (4, 1, 2, 3)])
def test_gamma_row_roundtrip_every_offset(shape):
    for off in range(moa.pi(shape)):
        idx = moa.gamma_row_inverse(off, shape)
        assert moa.gamma_row(idx, shape) == off
    for idx in moa.iota(shape).reshape(-1, len(shape)):
        idx = tuple(int(i) for i in idx)
        assert moa.gamma_row_inverse(moa.gamma_row(idx, shape), shape) == idx


@pytest.mark.parametrize("m,n,bm,bn", [(4, 6, 2, 3), (8, 8, 4, 2), (6, 4, 3, 4)])
def test_gamma_blocked_is_lifted_row_major(m, n, bm, bn):
    """gamma_blocked == gamma_row over the dimension-lifted index
    (i_o, j_o, i_i, j_i) with the lifted shape — blocking IS lifting."""
    for i, j in itertools.product(range(m), range(n)):
        lifted_idx = (i // bm, j // bn, i % bm, j % bn)
        lifted_shape = (m // bm, n // bn, bm, bn)
        assert moa.gamma_blocked((i, j), (m, n), (bm, bn)) == \
            moa.gamma_row(lifted_idx, lifted_shape)


def test_lift_loop_rewrite_preserves_gamma_offsets():
    """The affine access rewrite of lift_loop resolves to the SAME flat
    offsets as gamma_row on the unsplit index — layout is untouched."""
    m, n, p = 8, 6, 4
    o = onf.gemm_onf(m, n, p)
    lifted = onf.lift_loop(o, "i", 2, "proc")
    a_acc = lifted.ins[0]          # A, coeffs over i_o/i_i/k
    for i, k in itertools.product(range(m), range(n)):
        env = {"i_o": i // (m // 2), "i_i": i % (m // 2), "k": k, "j": 0}
        assert a_acc.offset(env) == moa.gamma_row((i, k), (m, n))


# ---------------------------------------------------------------------------
# derivation structure: the schedule reproduces the hand-written layout
# ---------------------------------------------------------------------------

def test_derived_gemm_schedule_matches_handwritten_layout():
    m, k, n = 256, 192, 128
    bm, bk, bn = 64, 48, 32
    lifted = onf.gemm_fully_lifted(m, k, n, procs=m // bm, bk=bk, bn=bn)
    s = sched.derive_schedule(lifted)
    assert s.grid_extents == (m // bm, n // bn, k // bk)
    assert s.dimension_semantics == ("parallel", "parallel", "arbitrary")
    a, b = s.ins
    assert (a.block, a.grid_dims) == ((bm, bk), (0, 2))
    assert (b.block, b.grid_dims) == ((bk, bn), (2, 1))
    assert (s.out.block, s.out.grid_dims) == ((bm, bn), (0, 1))
    assert s.contracted == ("k",) and s.needs_scratch


def test_derived_expert_schedule_lifts_expert_axis():
    s = sched.derive_schedule(
        onf.expert_gemm_fully_lifted(4, 64, 96, 32, bm=32, bk=48, bn=32))
    assert s.grid_extents == (4, 2, 1, 2)
    assert s.dimension_semantics == ("parallel",) * 3 + ("arbitrary",)
    assert s.ins[0].block == (1, 32, 48)      # expert axis rides as block 1
    assert s.out.grid_dims == (0, 1, 2)


def test_derive_requires_a_lifted_nest():
    with pytest.raises(ValueError, match="lift"):
        sched.derive_schedule(onf.gemm_onf(8, 8, 8))


def test_derive_handles_nested_double_lift():
    """Lifting a lifted axis again (i -> i_o -> i_i_o) is a deeper hierarchy,
    not an error: the derivation treats i and i_i as nested logical axes and
    the emitted kernel still reproduces the GEMM."""
    o = onf.gemm_onf(16, 16, 16)
    o = onf.lift_loop(o, "i", 2, "proc")
    o = onf.lift_loop(o, "i_i", 2, "vector")
    s = sched.derive_schedule(o)
    assert s.grid_extents == (2, 2)
    assert s.dimension_semantics == ("parallel", "parallel")
    fn = emit_pallas(s, out_dtype=jnp.float32, interpret=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    a = jax.random.normal(k1, (16, 16), jnp.float32)
    b = jax.random.normal(k2, (16, 16), jnp.float32)
    # operands arrive in the lifted view — a pure gamma re-layout (reshape)
    got = fn(a.reshape(s.ins[0].shape), b.reshape(s.ins[1].shape))
    assert _err(got.reshape(16, 16), jnp.dot(a, b)) < 1e-4


# ---------------------------------------------------------------------------
# keystone: emitted kernel == ONF oracle == jnp.dot (interpret mode)
# ---------------------------------------------------------------------------

def test_emit_derived_gemm_matches_onf_oracle_and_dot():
    m, k, n = 32, 48, 16
    lifted = onf.gemm_fully_lifted(m, k, n, procs=4, bk=16, bn=8)
    fn = emit_pallas(sched.derive_schedule(lifted), out_dtype=jnp.float32,
                     interpret=True)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (m, k), jnp.float32)
    b = jax.random.normal(k2, (k, n), jnp.float32)
    got = fn(a, b)
    want_onf = lifted.execute(np.zeros(m * n, np.float32),
                              np.asarray(a).ravel(), np.asarray(b).ravel())
    assert _err(got, want_onf.reshape(m, n)) < 1e-4
    assert _err(got, jnp.dot(a, b)) < 1e-4
    # and the flat ONF form (paper eq. 3) agrees too
    want_flat = moa.onf_gemm(np.asarray(a).ravel(), np.asarray(b).ravel(),
                             m, k, n)
    assert _err(got, want_flat.reshape(m, n)) < 1e-4


@pytest.mark.parametrize("m,k,n", [(129, 257, 127), (100, 70, 130), (1, 1, 1),
                                   (8, 1024, 8)])
def test_derived_path_non_divisible_shapes(m, k, n):
    """Padding/masking path: ops.moa_gemm pads to block multiples, runs the
    derived schedule, slices back — must match jnp.dot exactly in shape."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(k1, (m, k), jnp.float32)
    b = jax.random.normal(k2, (k, n), jnp.float32)
    got = ops.moa_gemm(a, b, interpret=True)
    want = jnp.dot(a, b, preferred_element_type=jnp.float32)
    assert got.shape == (m, n)
    assert _err(got, want) < 5e-5 * max(k, 1)


@pytest.mark.parametrize("op,shapes", [
    ("gemm", (37, 23, 41)),
    ("expert", (3, 18, 12, 10)),
    ("hadamard", (37, 141)),
])
def test_derived_bit_identical_to_onf_oracle(op, shapes):
    """Interpret-mode kernels are bit-identical to the ONF oracle
    (``Onf.execute``) on integer-valued f32 inputs, where every summation
    order produces the same exact floats — including padded remainder
    blocks.  This replaced the legacy hand-written-kernel cross-check when
    those kernels were removed."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))

    def ints(key, shape):
        return jax.random.randint(key, shape, -4, 5).astype(jnp.float32)

    if op == "gemm":
        m, k, n = shapes
        a, b = ints(k1, (m, k)), ints(k2, (k, n))
        got = ops.moa_gemm(a, b, interpret=True)
        o = onf.gemm_onf(m, k, n)
        want = o.execute(o.init_out(m * n), np.asarray(a).ravel(),
                         np.asarray(b).ravel()).reshape(m, n)
    elif op == "expert":
        e, cap, d, f = shapes
        x, w = ints(k1, (e, cap, d)), ints(k2, (e, d, f))
        got = ops.expert_gemm(x, w, interpret=True)
        o = onf.expert_gemm_onf(e, cap, d, f)
        want = o.execute(o.init_out(e * cap * f), np.asarray(x).ravel(),
                         np.asarray(w).ravel()).reshape(e, cap, f)
    else:
        m, n = shapes
        a = ints(k1, (m, n))
        got = ops.hadamard(a, a, interpret=True)
        o = onf.hadamard_onf(m, n)
        want = o.execute(o.init_out(m * n), np.asarray(a).ravel(),
                         np.asarray(a).ravel()).reshape(m, n)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_unified_matmul_entry_collapses_batch_and_head_dims():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (2, 5, 16), jnp.float32)
    w = jax.random.normal(k2, (16, 3, 4), jnp.float32)
    got = ops.matmul(x, w, interpret=True)          # forced kernel path
    want = jnp.einsum("bsd,dhk->bshk", x, w)
    assert got.shape == (2, 5, 3, 4)
    assert _err(got, want) < 1e-4
    # XLA-oracle dispatch (no interpret flag on a CPU entry) agrees too
    with hw.use_hardware("v100"):
        assert _err(ops.matmul(x, w), want) < 1e-4


def test_unified_matmul_is_differentiable_through_kernel():
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    x = jax.random.normal(k1, (6, 8), jnp.float32)
    w = jax.random.normal(k2, (8, 4), jnp.float32)

    def loss(xx, ww):
        return (ops.matmul(xx, ww, interpret=True) ** 2).sum()

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * (x @ w) @ w.T),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(2 * x.T @ (x @ w)),
                               rtol=1e-4, atol=1e-4)


def test_expert_matmul_entry_matches_einsum():
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(k1, (3, 10, 12), jnp.float32)
    w = jax.random.normal(k2, (3, 12, 6), jnp.float32)
    want = jnp.einsum("ecd,edf->ecf", x, w)
    assert _err(ops.expert_matmul(x, w, interpret=True), want) < 1e-4
    assert _err(ops.expert_matmul(x, w), want) < 1e-4


# ---------------------------------------------------------------------------
# the schedule cache: repeated calls never re-run solve_blocks
# ---------------------------------------------------------------------------

def test_schedule_cache_keyed_on_normal_form():
    sched.reset_schedule_cache()
    entry = hw.get_entry("cpu")
    gemm = expr.matmul_expr(300, 200, 100)
    b0 = sched.get_schedule(gemm, dtype="float32", hardware=entry)
    after_first = sched.schedule_cache_stats()
    assert after_first["misses"] == 1 and after_first["solves"] == 1
    b1 = sched.get_schedule(gemm, dtype="float32", hardware=entry)
    after_second = sched.schedule_cache_stats()
    assert b1 is b0
    assert after_second["hits"] == 1
    assert after_second["solves"] == 1          # no repeated brute-force work
    # a structurally identical expression is the SAME cache line — the
    # normal form, not object identity or a string name, is the key
    again = expr.inner("add", "mul", expr.arr("A", (300, 200)),
                       expr.arr("B", (200, 100)))
    assert sched.get_schedule(again, dtype="float32", hardware=entry) is b0
    # a different hardware entry is a different cache line
    sched.get_schedule(gemm, dtype="float32", hardware=hw.get_entry("v100"))
    assert sched.schedule_cache_stats()["misses"] == 2


def test_deprecated_string_op_lands_on_expression_cache_line():
    """The one-release string signature still works (with a warning) and
    shares cache lines with the equivalent expression."""
    sched.reset_schedule_cache()
    entry = hw.get_entry("cpu")
    b0 = sched.get_schedule(expr.matmul_expr(64, 32, 48), dtype="float32",
                            hardware=entry)
    with pytest.deprecated_call():
        b1 = sched.get_schedule("gemm", (64, 32, 48), "float32", entry)
    assert b1 is b0
    assert sched.schedule_cache_stats()["hits"] == 1
    with pytest.raises(ValueError, match="unknown schedule op"):
        with pytest.deprecated_call():
            sched.get_schedule("conv", (1, 2, 3), "float32", entry)


def test_transposed_and_col_layout_share_a_normal_form():
    """transpose(row-major (n,k)) and col-major (k,n) psi-reduce to the same
    flat coefficients, hence the same schedule-cache line."""
    sched.reset_schedule_cache()
    entry = hw.get_entry("cpu")
    via_transpose = expr.inner("add", "mul", expr.arr("A", (32, 16)),
                               expr.transpose(expr.arr("B", (24, 16))))
    via_col = expr.inner("add", "mul", expr.arr("A", (32, 16)),
                         expr.arr("B", (16, 24), layout="col"))
    b0 = sched.get_schedule(via_transpose, dtype="float32", hardware=entry)
    b1 = sched.get_schedule(via_col, dtype="float32", hardware=entry)
    assert b1 is b0
    assert sched.schedule_cache_stats() == {"hits": 1, "misses": 1,
                                            "solves": 1}


def test_ops_path_reuses_cached_schedule():
    sched.reset_schedule_cache()
    k1, k2 = jax.random.split(jax.random.PRNGKey(6))
    a = jax.random.normal(k1, (96, 64), jnp.float32)
    b = jax.random.normal(k2, (64, 80), jnp.float32)
    ops.moa_gemm(a, b, interpret=True)
    solves = sched.schedule_cache_stats()["solves"]
    for _ in range(3):
        ops.moa_gemm(a, b, interpret=True)
    assert sched.schedule_cache_stats()["solves"] == solves


# ---------------------------------------------------------------------------
# hardware registry
# ---------------------------------------------------------------------------

def test_registry_detects_and_overrides():
    entry = hw.detect_hardware()
    assert entry.name in hw.registered_hardware()
    if jax.default_backend() == "cpu":
        assert entry.name == "cpu" and entry.interpret
    with hw.use_hardware("tpu_v5e") as forced:
        assert forced.backend == "pallas" and not forced.interpret
        assert hw.current_hardware().name == "tpu_v5e"
    assert hw.current_hardware().name == entry.name
    with pytest.raises(KeyError):
        hw.get_entry("dgx-imaginary")


def test_vmem_validation_rejects_oversized_blocks():
    huge = BlockChoice(bm=4096, bk=4096, bn=4096, vmem_bytes=0,
                       arithmetic_intensity=0, utilization=1)
    with pytest.raises(ValueError, match="VMEM"):
        sched.get_schedule(expr.matmul_expr(8192, 8192, 8192),
                           dtype="float32", hardware=hw.get_entry("cpu"),
                           blocks=huge)
