"""Paper §3.3/§3.4: a-priori block-size derivation from shapes + hardware.

Derived: the solver's choices for the paper's V100 table (must reproduce
32x32 -> 64x64 doubles) and for v5e across the assigned-architecture GEMM
shapes, with the '3 blocks <= L1/VMEM' accounting shown explicitly.
Measured: Pallas interpret-mode kernel wall time at two block choices
(same result, different lifting — demonstrating block choice is semantics-
preserving, which is the algebra's point).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core.blocking import BlockChoice, solve_blocks, solve_blocks_square
from repro.core.lifting import TPU_V5E, V100
from repro.kernels import ops


def run():
    rows = []
    b32 = solve_blocks_square(V100, "float64", n_arrays=3)
    rows.append(("blocking/v100_l1", "-",
                 f"block={b32}x{b32} doubles bytes={3 * b32 * b32 * 8} "
                 f"<= L1 32KiB (paper: 32)"))
    shared = dataclasses.replace(
        V100, vmem=dataclasses.replace(V100.vmem, capacity_bytes=128 * 2**10))
    b64 = solve_blocks_square(shared, "float64")
    rows.append(("blocking/v100_shared_l1", "-",
                 f"block={b64}x{b64} (paper: 64 at the 9K-matrix regime)"))
    # v5e choices for representative GEMMs of the assigned archs
    for name, (m, k, n) in {
        "command-r-ffn": (4096, 12288, 33792),
        "gemma-ffn": (4096, 2048, 16384),
        "deepseek-expert": (384, 2048, 1408),
        "mamba2-inproj": (4096, 1536, 6500),
    }.items():
        bc = solve_blocks(m, k, n, "bfloat16", TPU_V5E)
        rows.append((f"blocking/v5e/{name}", "-",
                     f"blocks={bc.as_tuple()} vmem_KiB={bc.vmem_bytes // 1024} "
                     f"AI={bc.arithmetic_intensity:.0f}flops/B"))
    # measured: same GEMM under two liftings, identical semantics
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (256, 256), jnp.float32)
    b = jax.random.normal(k2, (256, 256), jnp.float32)
    for bm in [64, 128]:
        bc = BlockChoice(bm, bm, bm, 0, 0.0, 1.0)
        us = time_fn(lambda: ops.moa_gemm(a, b, blocks=bc, interpret=True),
                     warmup=1, iters=3)
        rows.append((f"blocking/interpret_b{bm}", us,
                     "same-result different-lifting"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
