"""Paper figs 6-8: time & energy vs matrix size, per block size.

CPU-measured: XLA matmul wall time for small N (context anchor).
Derived: modeled v5e time + energy per (N, block) from the roofline/energy
model — the reproduction of the figures' shape: energy tracks time; the
solver-predicted block is optimal; both transition memory->compute bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import energy
from repro.core.blocking import solve_blocks

BLOCKS = [128, 256, 512, 1024]
SIZES = [2048, 4096, 8192, 16384]


def run():
    rows = []
    # measured anchor: this host's XLA GEMM
    for n in [256, 512, 1024]:
        a = jnp.ones((n, n), jnp.float32)
        f = jax.jit(lambda x: x @ x)
        us = time_fn(f, a)
        rows.append((f"gemm_sweep/cpu_xla/N{n}", us,
                     f"gflops={2 * n**3 / us / 1e3:.1f}"))
    # derived: the paper's figures on v5e constants
    for n in SIZES:
        for b, rep in energy.energy_vs_blocksize(n, BLOCKS):
            rows.append((f"gemm_sweep/v5e_model/N{n}/b{b}", "-",
                         f"time_s={rep.time_s:.4e} energy_J={rep.energy_J:.3f} "
                         f"power_W={rep.power_W:.0f} bound={rep.bound}"))
        bc = solve_blocks(n, n, n, "bfloat16")
        rep = energy.gemm_energy(n, n, n, bc)
        rows.append((f"gemm_sweep/v5e_model/N{n}/solver{bc.as_tuple()}", "-",
                     f"time_s={rep.time_s:.4e} energy_J={rep.energy_J:.3f} "
                     f"power_W={rep.power_W:.0f} bound={rep.bound} <= optimal"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
