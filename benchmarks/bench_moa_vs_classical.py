"""Paper §3.8 / refs [29,30]: MoA contiguous GEMM vs classical row-column.

Three layers of evidence (CPU host, TPU modeled):
  1. measured: vectorized ONF execution — MoA's inner loop is a contiguous
     row AXPY; the classical inner loop gathers a stride-p column of B.
  2. measured: cache-line traffic counts from the symbolic access traces.
  3. derived: modeled TPU HBM traffic blocked vs naive (the quantity the
     paper's contiguity argument minimizes).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import time_fn
from repro.core import energy, moa
from repro.core.blocking import solve_blocks


def moa_gemm_vectorized(a: np.ndarray, b_flat: np.ndarray, m, n, p):
    """ONF loop order (i, k, j): contiguous row ops only."""
    c = np.zeros((m, p))
    b2 = b_flat.reshape(n, p)
    for i in range(m):
        row = c[i]
        ai = a[i]
        for k in range(n):
            row += ai[k] * b2[k]          # stride-1 AXPY
    return c


def classical_gemm_vectorized(a: np.ndarray, b_flat: np.ndarray, m, n, p):
    """Row x column: the k-loop vectorizes only as a stride-p gather."""
    c = np.zeros((m, p))
    for i in range(m):
        ai = a[i]
        for j in range(p):
            c[i, j] = ai @ b_flat[j::p]   # strided column of B
    return c


def run():
    rows = []
    rng = np.random.default_rng(0)
    for n in [64, 128, 256]:
        a = rng.standard_normal((n, n))
        b = rng.standard_normal((n, n))
        bf = b.ravel()
        want = a @ b
        t_moa = time_fn(lambda: moa_gemm_vectorized(a, bf, n, n, n),
                        warmup=1, iters=3)
        t_cls = time_fn(lambda: classical_gemm_vectorized(a, bf, n, n, n),
                        warmup=1, iters=3)
        got = moa_gemm_vectorized(a, bf, n, n, n)
        assert np.allclose(got, want)
        rows.append((f"moa_vs_classical/N{n}/moa_onf", t_moa,
                     f"speedup={t_cls / t_moa:.2f}x"))
        rows.append((f"moa_vs_classical/N{n}/classical", t_cls, ""))
        tr_m = moa.cacheline_traffic(moa.moa_access_trace(n, n, n), n, n, n)
        tr_c = moa.cacheline_traffic(moa.classical_access_trace(n, n, n), n, n, n)
        rows.append((f"moa_vs_classical/N{n}/lines", "-",
                     f"moa_lines={tr_m} classical_lines={tr_c} "
                     f"ratio={tr_c / max(tr_m, 1):.1f}"))
    # derived TPU traffic: blocked-contiguous vs naive strided
    for n in [4096, 16384]:
        bc = solve_blocks(n, n, n, "bfloat16")
        hbm_b, _ = energy.gemm_traffic(n, n, n, bc)
        hbm_n = energy.gemm_unblocked_traffic(n, n, n)
        rows.append((f"moa_vs_classical/N{n}/tpu_traffic", "-",
                     f"blocked_GB={hbm_b / 1e9:.1f} naive_GB={hbm_n / 1e9:.0f} "
                     f"reduction={hbm_n / hbm_b:.0f}x blocks={bc.as_tuple()}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
