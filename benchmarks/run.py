"""Benchmark aggregator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <substr>]

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = '-' for
model-only rows; this host is CPU — TPU numbers are derived from the
roofline/energy models and the dry-run artifacts).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import emit

SECTIONS = [
    ("paper_sec3.4_blocking", "benchmarks.bench_blocking"),
    ("paper_figs6-8_gemm_sweep", "benchmarks.bench_gemm_sweep"),
    ("paper_figs9-11_energy", "benchmarks.bench_energy_model"),
    ("paper_refs29-30_moa_vs_classical", "benchmarks.bench_moa_vs_classical"),
    ("kernels", "benchmarks.bench_kernels"),
    ("schedule_derived_vs_oracle", "benchmarks.bench_schedule"),
    ("paper_table1_roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for title, mod_name in SECTIONS:
        if args.only and args.only not in title:
            continue
        print(f"# --- {title} ---")
        try:
            mod = __import__(mod_name, fromlist=["run"])
            emit(mod.run())
        except Exception as e:
            failed.append(title)
            print(f"{title},ERROR,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(f"benchmark sections failed: {failed}")


if __name__ == "__main__":
    main()
