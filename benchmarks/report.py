"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON artifacts.

    PYTHONPATH=src python -m benchmarks.report [--dir results/dryrun] > table.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.core.lifting import TPU_V5E


def load(dirname):
    recs = [json.load(open(f)) for f in sorted(glob.glob(os.path.join(dirname, "*.json")))]
    return recs


def min_decode_bytes(rec) -> float:
    """Analytic floor for one decode step: read every (active) param once +
    the whole KV cache once (global bytes)."""
    p_active = rec["params_active"]
    return p_active * 2.0  # bf16 params; cache added by caller if known


def emit_dryrun(recs):
    print("| arch | shape | mesh | status | compile_s | args/dev | temp/dev | collectives (count) |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("status") == "SKIP":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | - | - | - | {r['reason'][:60]}… |")
            continue
        if r.get("status") != "OK":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | - | - | - | {r.get('error','')[:60]} |")
            continue
        mem = r["memory"]
        args_gb = mem.get("argument_size_in_bytes", 0) / 2**30
        temp_gb = mem.get("temp_size_in_bytes", 0) / 2**30
        colls = ", ".join(f"{k}:{v}" for k, v in
                          sorted(r.get("collectives_count", {}).items()))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
              f"{r['compile_s']} | {args_gb:.2f} GiB | {temp_gb:.2f} GiB | {colls} |")


def emit_roofline(recs):
    print("| arch | shape | compute_s | memory_s | collective_s | dominant | "
          "MODEL_FLOPS | useful ratio | roofline frac | would move the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    hints = {
        ("memory", "train"): "less HLO traffic: fused attention, saved-activation policy, bf16 scores",
        ("memory", "prefill"): "chunked/flash attention (no S^2 scores), cache write fusion",
        ("memory", "decode"): "already bandwidth-bound: shrink cache (window/latent/quant), fuse gathers",
        ("collective", "train"): "shard-local MoE dispatch (kill global sort all-to-alls), overlap",
        ("collective", "prefill"): "shard-local MoE dispatch; fewer FSDP all-gathers via better weight layout",
        ("collective", "decode"): "replicate small weights; batch collectives",
        ("compute", "train"): "remat policy (save dots), MXU-aligned shapes",
        ("compute", "prefill"): "MXU-aligned head dims",
        ("compute", "decode"): "kernel fusion",
    }
    for r in recs:
        if r.get("status") != "OK" or r.get("mesh") != "single":
            continue
        rl = r["roofline"]
        hint = hints.get((rl["dominant"], r["kind"]), "")
        print(f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
              f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
              f"{rl['dominant']} | {rl['model_flops']:.2e} | "
              f"{rl['useful_flops_ratio']:.2f} | {rl['roofline_fraction']:.4f} | {hint} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    recs = load(args.dir)
    recs.sort(key=lambda r: (r["arch"], r["shape"], r.get("mesh", "")))
    if args.section in ("dryrun", "both"):
        print("### Dry-run matrix\n")
        emit_dryrun(recs)
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline (single-pod, 256 chips)\n")
        emit_roofline(recs)


if __name__ == "__main__":
    main()
