"""Derived-schedule kernels (expression-keyed) vs the jnp oracles.

Seeds the perf trajectory for the Schedule subsystem: wall-clock on this host
(interpret-mode Pallas on CPU — the correctness path; TPU is the perf target)
plus the modeled TPU time/energy from ``core.energy`` for the block choice the
schedule cache derived.  Rows cover the redesigned expression API: the plain
derived GEMM, the transposed-operand ``matmul(transpose_b=True)`` schedule
(column-gamma coefficients, no relayout copy), the max-plus semiring through
the same emitter, and ``matmul_sharded`` rows — the derived DistributedPlan
per sharding kind on an 8-way mesh, with its collective choice and modeled
per-device HBM residency + interconnect bytes.  The training rows time the
derived backward passes (``flash_backward``, ``ssd_backward`` — the custom
VJPs running the dQ/dKdV and reverse-scan recurrence kinds) against the
jitted jnp-oracle recompute, and ``matmul_bf16_acc`` exercises the bf16
accumulation semiring (tiles solved for 2-byte partial sums).  Also writes
``BENCH_schedule.json`` at the repo root so later PRs can diff the
trajectory; ``benchmarks/check_regression.py`` gates CI on it.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import expr as E
from repro.core import schedule as sched
from repro.core.energy import attention_energy, gemm_energy, scan_energy
from repro.core.hardware import get_entry
from repro.core.mesh import MeshShape
from repro.distributed import plan as dplan
from repro.kernels import ops
from repro.models.chunked_attention import chunked_attention

SHAPES = [(128, 128, 128), (256, 256, 256), (100, 70, 130)]
#: bf16-accumulation rows: the semiring solver sizes tiles for 2-byte
#: partial sums (acc_dtype="bfloat16"), vs the default f32 accumulator
BF16_ACC_SHAPES = [(256, 256, 256), (512, 512, 512)]
#: flash-attention rows: (batch, q_heads, kv_heads, seq, head_dim)
ATTN_SHAPES = [(1, 4, 2, 512, 64), (1, 4, 2, 300, 64)]
#: backward rows reuse the first attention/ssd shape: derived-VJP grad vs
#: the jitted jnp-oracle grad, plus the dq/dkv (resp. reverse-scan) bundles
BWD_ATTN_SHAPE = ATTN_SHAPES[0]
#: ssd-scan rows: (batch, seq, heads, head_dim, state_dim)
SSD_SHAPES = [(1, 512, 4, 32, 32), (1, 300, 4, 32, 32)]
BWD_SSD_SHAPE = SSD_SHAPES[0]
#: the distributed-plan rows model an 8-way slice of the v5e "data" ring
MESH8 = MeshShape((("x", 8),))
#: sharding kinds for the matmul_sharded rows (collective derived, then
#: modeled per-device HBM residency + interconnect bytes)
SHARDINGS = [("row", {"m": "x"}, {}),
             ("sigma", {"k": "x"}, {}),
             ("gather", {"m": "x"}, {"replicate_out": True})]
JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_schedule.json")


def run():
    rows, records = [], []
    entry = get_entry("tpu_v5e")
    for m, k, n in SHAPES:
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(k1, (m, k), jnp.float32)
        b = jax.random.normal(k2, (k, n), jnp.float32)
        bt = jax.random.normal(k2, (n, k), jnp.float32)
        tag = f"schedule/gemm_{m}x{k}x{n}"
        us_derived = time_fn(lambda: ops.moa_gemm(a, b, interpret=True),
                             warmup=1, iters=3)
        us_xla = time_fn(jax.jit(lambda x, y: jnp.dot(x, y)), a, b)
        us_tb = time_fn(lambda: ops.matmul(a, bt, transpose_b=True,
                                           interpret=True),
                        warmup=1, iters=3)
        us_tb_xla = time_fn(jax.jit(
            lambda x, y: jnp.einsum("mk,nk->mn", x, y)), a, bt)
        us_maxplus = time_fn(lambda: ops.semiring_matmul(
            a, b, plus="max", times="add", interpret=True), warmup=1, iters=3)
        us_maxplus_xla = time_fn(jax.jit(
            lambda x, y: jnp.max(x[:, :, None] + y[None, :, :], axis=1)),
            a, b)

        bundle = sched.get_schedule(E.matmul_expr(m, k, n), dtype="float32",
                                    hardware=entry)
        rep = gemm_energy(m, k, n, bundle.blocks, "float32",
                          hardware=entry.shape)
        derived = (f"blocks={bundle.blocks.as_tuple()} "
                   f"modeled_t={rep.time_s:.3e}s E={rep.energy_J:.3e}J")
        tb_bundle = sched.get_schedule(
            E.matmul_expr(m, k, n, transpose_b=True), dtype="float32",
            hardware=entry)
        mp_bundle = sched.get_schedule(
            E.inner("max", "add", E.arr("A", (m, k)), E.arr("B", (k, n))),
            dtype="float32", hardware=entry)
        rows.append((f"{tag}/derived", us_derived, derived))
        rows.append((f"{tag}/jnp_dot", us_xla, "XLA oracle"))
        rows.append((f"{tag}/matmul_transpose_b", us_tb,
                     "derived transposed-operand (column-gamma, no copy)"))
        rows.append((f"{tag}/transpose_b_jnp", us_tb_xla, "XLA dot_general"))
        rows.append((f"{tag}/maxplus", us_maxplus,
                     "tropical semiring, same emitter"))
        rows.append((f"{tag}/maxplus_jnp", us_maxplus_xla,
                     "XLA broadcast+fold oracle"))
        sharded = {}
        for kind, shard, kw in SHARDINGS:
            plan = dplan.matmul_plan(m, k, n, MESH8, shard=shard,
                                     hardware=entry, **kw)
            hbm = plan.hbm_bytes_per_device("float32")
            ici = plan.ici_bytes_per_device("float32")
            sharded[kind] = {"collective": plan.collective,
                             "dropped": [list(d) for d in plan.dropped],
                             "hbm_bytes_per_device": hbm,
                             "ici_bytes_per_device": ici}
            rows.append((f"{tag}/matmul_sharded_{kind}", "-",
                         f"collective={plan.collective} HBM/dev={hbm}B "
                         f"ICI/dev={ici}B (derived plan, 8-way mesh)"))
        records.append({
            "shape": [m, k, n],
            "us_derived_interpret": us_derived,
            "us_jnp_dot": us_xla,
            "us_transpose_b_interpret": us_tb,
            "us_transpose_b_jnp": us_tb_xla,
            "us_maxplus_interpret": us_maxplus,
            "us_maxplus_jnp": us_maxplus_xla,
            "blocks": list(bundle.blocks.as_tuple()),
            "grid": list(bundle.schedule.grid_extents),
            "transpose_b_blocks": list(tb_bundle.blocks.as_tuple()),
            "maxplus_blocks": list(mp_bundle.blocks.as_tuple()),
            "modeled_time_s": rep.time_s,
            "modeled_energy_J": rep.energy_J,
            "modeled_power_W": rep.power_W,
            "bound": rep.bound,
            "sharded": sharded,
        })
    attn_records = []
    for b, hq, hkv, s, hd in ATTN_SHAPES:
        g = hq // hkv
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(k1, (b, s, hkv, g, hd), jnp.float32)
        k = jax.random.normal(k2, (b, s, hkv, hd), jnp.float32)
        v = jax.random.normal(k3, (b, s, hkv, hd), jnp.float32)
        scale = hd ** -0.5
        tag = f"schedule/flash_attention_{b}x{hq}x{s}x{hd}"
        us_flash = time_fn(lambda: ops.attention(q, k, v, scale=scale,
                                                 causal=True, interpret=True),
                           warmup=1, iters=3)
        us_chunk = time_fn(jax.jit(lambda q, k, v: chunked_attention(
            q, k, v, scale=scale, causal=True)), q, k, v)
        bundle = sched.get_schedule(E.attention_form(b, hkv, g, s, s, hd),
                                    dtype="float32", hardware=entry)
        rep = attention_energy(b, hq, s, s, hd, bundle.blocks,
                               "float32", causal=True, hardware=entry.shape)
        rows.append((f"{tag}/derived", us_flash,
                     f"streaming blocks={bundle.blocks.as_tuple()} "
                     f"modeled HBM={rep.hbm_bytes:.3e}B "
                     f"t={rep.time_s:.3e}s E={rep.energy_J:.3e}J"))
        rows.append((f"{tag}/chunked_jnp", us_chunk, "XLA online-softmax"))
        attn_records.append({
            "shape": [b, hq, hkv, s, hd],
            "us_flash_interpret": us_flash,
            "us_chunked_jnp": us_chunk,
            "stream_blocks": list(bundle.blocks.as_tuple()),
            "grid": list(bundle.schedule.grid_extents),
            "modeled_hbm_bytes": rep.hbm_bytes,
            "modeled_time_s": rep.time_s,
            "modeled_energy_J": rep.energy_J,
            "bound": rep.bound,
        })
    ssd_records = []
    for b, s, h, p, n in SSD_SHAPES:
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(2), 4)
        xdt = jax.random.normal(k1, (b, s, h, p), jnp.float32)
        dA = -jnp.abs(jax.random.normal(k2, (b, s, h), jnp.float32)) * 0.3
        B = jax.random.normal(k3, (b, s, n), jnp.float32)
        C = jax.random.normal(k4, (b, s, n), jnp.float32)
        chunk = ops.default_ssd_chunk(s, h, p, n, "float32", entry)
        chunk = min(chunk, s)
        tag = f"schedule/ssd_scan_{b}x{s}x{h}x{p}x{n}"
        us_derived = time_fn(lambda: ops.scan_ssd(
            xdt, dA, B, C, chunk=chunk, interpret=True)[0],
            warmup=1, iters=3)
        us_oracle = time_fn(jax.jit(lambda *a: ops._ssd_oracle(
            *a, jnp.zeros((b, h, p, n), jnp.float32), chunk)[0]),
            xdt, dA, B, C)
        bundle = sched.get_schedule(
            E.ssd_form(b, -(-s // chunk), chunk, h, p, n), dtype="float32",
            hardware=entry, blocks=(chunk,))
        rep = scan_energy(b, s, h, p, n, bundle.blocks, "float32",
                          hardware=entry.shape)
        rep_mat = scan_energy(b, s, h, p, n, bundle.blocks, "float32",
                              materialized=True, hardware=entry.shape)
        rows.append((f"{tag}/derived", us_derived,
                     f"chunk={chunk} (solved) modeled HBM={rep.hbm_bytes:.3e}B "
                     f"t={rep.time_s:.3e}s E={rep.energy_J:.3e}J"))
        rows.append((f"{tag}/hand_rolled_jnp", us_oracle,
                     f"modeled HBM={rep_mat.hbm_bytes:.3e}B (L + scores "
                     "round-trip HBM) E=" + f"{rep_mat.energy_J:.3e}J"))
        ssd_records.append({
            "shape": [b, s, h, p, n],
            "chunk": chunk,
            "us_derived_interpret": us_derived,
            "us_hand_rolled_jnp": us_oracle,
            "grid": list(bundle.schedule.grid_extents),
            "modeled_hbm_bytes": rep.hbm_bytes,
            "modeled_hbm_bytes_materialized": rep_mat.hbm_bytes,
            "modeled_time_s": rep.time_s,
            "modeled_energy_J": rep.energy_J,
            "modeled_energy_J_materialized": rep_mat.energy_J,
            "bound": rep.bound,
        })
    # ---- derived backward passes (ISSUE 6): flash dQ/dKdV + SSD reverse -
    b, hq, hkv, s, hd = BWD_ATTN_SHAPE
    g = hq // hkv
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(k1, (b, s, hkv, g, hd), jnp.float32)
    k = jax.random.normal(k2, (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(k3, (b, s, hkv, hd), jnp.float32)
    scale = hd ** -0.5
    tag = f"schedule/flash_backward_{b}x{hq}x{s}x{hd}"
    grad_derived = jax.grad(lambda *a: (ops.attention(
        *a, scale=scale, causal=True, interpret=True) ** 2).sum(),
        argnums=(0, 1, 2))
    grad_oracle = jax.jit(jax.grad(lambda *a: (ops._oracle_attention(
        *a, scale, True, 0, 0) ** 2).sum(), argnums=(0, 1, 2)))
    us_bwd = time_fn(lambda: grad_derived(q, k, v), warmup=1, iters=3)
    us_bwd_oracle = time_fn(grad_oracle, q, k, v, warmup=1, iters=3)
    dq_bundle = sched.get_schedule(E.attention_dq_form(b, hkv, g, s, s, hd),
                                   dtype="float32", hardware=entry)
    dkv_bundle = sched.get_schedule(E.attention_dkv_form(b, hkv, g, s, s, hd),
                                    dtype="float32", hardware=entry)
    rep_dq = attention_energy(b, hq, s, s, hd, dq_bundle.blocks, "float32",
                              causal=True, hardware=entry.shape)
    rep_dkv = attention_energy(b, hq, s, s, hd, dkv_bundle.blocks, "float32",
                               causal=True, hardware=entry.shape)
    rows.append((f"{tag}/derived", us_bwd,
                 f"dq blocks={dq_bundle.blocks.as_tuple()} "
                 f"dkv blocks={dkv_bundle.blocks.as_tuple()} modeled "
                 f"t={rep_dq.time_s + rep_dkv.time_s:.3e}s "
                 f"E={rep_dq.energy_J + rep_dkv.energy_J:.3e}J (two passes)"))
    rows.append((f"{tag}/oracle_recompute", us_bwd_oracle,
                 "jitted grad through the chunked-jnp oracle"))
    flash_bwd_record = {
        "shape": [b, hq, hkv, s, hd],
        "us_bwd_derived_interpret": us_bwd,
        "us_bwd_oracle_jit": us_bwd_oracle,
        "dq_blocks": list(dq_bundle.blocks.as_tuple()),
        "dkv_blocks": list(dkv_bundle.blocks.as_tuple()),
        "modeled_time_s": rep_dq.time_s + rep_dkv.time_s,
        "modeled_energy_J": rep_dq.energy_J + rep_dkv.energy_J,
        "modeled_hbm_bytes": rep_dq.hbm_bytes + rep_dkv.hbm_bytes,
    }

    b, s, h, p, n = BWD_SSD_SHAPE
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(4), 4)
    xdt = jax.random.normal(k1, (b, s, h, p), jnp.float32)
    dA = -jnp.abs(jax.random.normal(k2, (b, s, h), jnp.float32)) * 0.3
    B = jax.random.normal(k3, (b, s, n), jnp.float32)
    C = jax.random.normal(k4, (b, s, n), jnp.float32)
    chunk = min(ops.default_ssd_chunk(s, h, p, n, "float32", entry), s)
    nc = -(-s // chunk)
    tag = f"schedule/ssd_backward_{b}x{s}x{h}x{p}x{n}"
    grad_derived = jax.grad(lambda *a: (ops.scan_ssd(
        *a, chunk=chunk, interpret=True)[0] ** 2).sum(), argnums=(0, 1, 2, 3))
    h0z = jnp.zeros((b, h, p, n), jnp.float32)
    grad_oracle = jax.jit(jax.grad(lambda *a: (ops._ssd_oracle(
        *a, h0z, chunk)[0] ** 2).sum(), argnums=(0, 1, 2, 3)))
    us_bwd = time_fn(lambda: grad_derived(xdt, dA, B, C), warmup=1, iters=3)
    us_bwd_oracle = time_fn(grad_oracle, xdt, dA, B, C, warmup=1, iters=3)
    bwd_bundle = sched.get_schedule(E.ssd_bwd_form(b, nc, chunk, h, p, n),
                                    dtype="float32", hardware=entry,
                                    blocks=(chunk,))
    rep_bwd = scan_energy(b, s, h, p, n, bwd_bundle.blocks, "float32",
                          hardware=entry.shape)
    rows.append((f"{tag}/derived", us_bwd,
                 f"chunk={chunk} (reverse stream) modeled "
                 f"t={rep_bwd.time_s:.3e}s E={rep_bwd.energy_J:.3e}J"))
    rows.append((f"{tag}/oracle_recompute", us_bwd_oracle,
                 "jitted grad through the chunked-jnp oracle"))
    ssd_bwd_record = {
        "shape": [b, s, h, p, n],
        "chunk": chunk,
        "us_bwd_derived_interpret": us_bwd,
        "us_bwd_oracle_jit": us_bwd_oracle,
        "modeled_time_s": rep_bwd.time_s,
        "modeled_energy_J": rep_bwd.energy_J,
        "modeled_hbm_bytes": rep_bwd.hbm_bytes,
    }

    # ---- bf16 accumulation semiring: tiles solved for 2-byte partials ----
    bf16_records = []
    for m, k, n in BF16_ACC_SHAPES:
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        a = jax.random.normal(k1, (m, k), jnp.bfloat16)
        bmat = jax.random.normal(k2, (k, n), jnp.bfloat16)
        tag = f"schedule/matmul_bf16_acc_{m}x{k}x{n}"
        us_acc = time_fn(lambda: ops.apply(
            E.matmul_expr(m, k, n), a, bmat, interpret=True,
            acc_dtype="bfloat16"), warmup=1, iters=3)
        us_jnp = time_fn(jax.jit(lambda x, y: jnp.dot(
            x, y, preferred_element_type=jnp.bfloat16)), a, bmat)
        acc_bundle = sched.get_schedule(E.matmul_expr(m, k, n),
                                        dtype="bfloat16", hardware=entry,
                                        acc_dtype="bfloat16")
        f32_bundle = sched.get_schedule(E.matmul_expr(m, k, n),
                                        dtype="bfloat16", hardware=entry)
        rep_acc = gemm_energy(m, k, n, acc_bundle.blocks, "bfloat16",
                              hardware=entry.shape)
        rows.append((f"{tag}/derived", us_acc,
                     f"blocks={acc_bundle.blocks.as_tuple()} "
                     f"(f32-acc: {f32_bundle.blocks.as_tuple()}) modeled "
                     f"t={rep_acc.time_s:.3e}s E={rep_acc.energy_J:.3e}J"))
        rows.append((f"{tag}/jnp_dot", us_jnp,
                     "XLA dot, preferred_element_type=bf16"))
        bf16_records.append({
            "shape": [m, k, n],
            "us_bf16_acc_interpret": us_acc,
            "us_jnp_dot": us_jnp,
            "blocks_bf16_acc": list(acc_bundle.blocks.as_tuple()),
            "blocks_f32_acc": list(f32_bundle.blocks.as_tuple()),
            "modeled_time_s": rep_acc.time_s,
            "modeled_energy_J": rep_acc.energy_J,
        })

    stats = sched.schedule_cache_stats()
    payload = {"hardware": entry.name, "mesh": list(MESH8.axes),
               "entries": records, "flash_attention": attn_records,
               "ssd_scan": ssd_records,
               "flash_backward": flash_bwd_record,
               "ssd_backward": ssd_bwd_record,
               "matmul_bf16_acc": bf16_records,
               "schedule_cache": stats,
               "plan_cache": dplan.plan_cache_stats()}
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("schedule/cache",
                 "-", f"hits={stats['hits']} misses={stats['misses']} "
                      f"solves={stats['solves']}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
