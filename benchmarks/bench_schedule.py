"""Derived-schedule kernels vs the legacy hand-written ones vs jnp.dot.

Seeds the perf trajectory for the Schedule subsystem: wall-clock on this host
(interpret-mode Pallas on CPU — the correctness path; TPU is the perf target)
plus the modeled TPU time/energy from ``core.energy`` for the block choice the
schedule cache derived.  Also writes ``BENCH_schedule.json`` at the repo root
so later PRs can diff the trajectory.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import schedule as sched
from repro.core.energy import gemm_energy
from repro.core.hardware import get_entry
from repro.kernels import ops

SHAPES = [(128, 128, 128), (256, 256, 256), (100, 70, 130)]
JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_schedule.json")


def run():
    rows, records = [], []
    entry = get_entry("tpu_v5e")
    for m, k, n in SHAPES:
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(k1, (m, k), jnp.float32)
        b = jax.random.normal(k2, (k, n), jnp.float32)
        tag = f"schedule/gemm_{m}x{k}x{n}"
        us_derived = time_fn(lambda: ops.moa_gemm(a, b, interpret=True),
                             warmup=1, iters=3)
        us_legacy = time_fn(lambda: ops.moa_gemm(a, b, interpret=True,
                                                 legacy=True),
                            warmup=1, iters=3)
        us_xla = time_fn(jax.jit(lambda x, y: jnp.dot(x, y)), a, b)

        bundle = sched.get_schedule("gemm", (m, k, n), "float32", entry)
        rep = gemm_energy(m, k, n, bundle.blocks, "float32",
                          hardware=entry.shape)
        derived = (f"blocks={bundle.blocks.as_tuple()} "
                   f"modeled_t={rep.time_s:.3e}s E={rep.energy_J:.3e}J")
        rows.append((f"{tag}/derived", us_derived, derived))
        rows.append((f"{tag}/legacy", us_legacy, "hand-written cross-check"))
        rows.append((f"{tag}/jnp_dot", us_xla, "XLA oracle"))
        records.append({
            "shape": [m, k, n],
            "us_derived_interpret": us_derived,
            "us_legacy_interpret": us_legacy,
            "us_jnp_dot": us_xla,
            "blocks": list(bundle.blocks.as_tuple()),
            "grid": list(bundle.schedule.grid_extents),
            "modeled_time_s": rep.time_s,
            "modeled_energy_J": rep.energy_J,
            "modeled_power_W": rep.power_W,
            "bound": rep.bound,
        })
    stats = sched.schedule_cache_stats()
    payload = {"hardware": entry.name, "entries": records,
               "schedule_cache": stats}
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("schedule/cache",
                 "-", f"hits={stats['hits']} misses={stats['misses']} "
                      f"solves={stats['solves']}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
