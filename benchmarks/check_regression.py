"""CI bench-regression gate: re-run the benched suites and diff against
their committed baselines (``BENCH_schedule.json``, ``BENCH_serve.json``).

The paper's energy claims only stay honest if every PR's numbers are
enforced ("Racing to Idle"): the modeled quantities — block choices, grids,
collectives, modeled time/energy/HBM — are pure functions of the derived
schedules, so any drift is a real behavior change and compares exact-ish
(rtol 1e-6).  Interpret-mode wall-clock timings are host noise on top of a
real signal, so they only fail when a fresh timing exceeds ``TIME_TOL``x
its baseline — catching an accidental oracle fallback or a schedule-cache
regression (order-of-magnitude slowdowns), not CI jitter.

Serving rows add throughput (``tok_s_`` prefix): a rate, so the
tolerance runs the other way — fresh may drop to ``1/TIME_TOL`` of
baseline before failing.  Dispatch-amortization ratios (``kernel_calls``
prefix — decode launches per generated token; the batched path's whole
point is pushing this below one per slot) gate like timings: lower is
better, fresh fails past ``TIME_TOL``x baseline.

A PR that intentionally changes a modeled number (new solver, new rows)
regenerates the affected baseline in the same commit::

    PYTHONPATH=src python -m benchmarks.bench_schedule
    PYTHONPATH=src python -m benchmarks.bench_serve

and this gate then pins the new trajectory.  Exit status: 0 clean,
1 on any regression (each violation printed).
"""
from __future__ import annotations

import json
import math
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
#: (baseline json, module whose run() regenerates it)
BASELINES = (("BENCH_schedule.json", "bench_schedule"),
             ("BENCH_serve.json", "bench_serve"))
#: interpret-mode timings: fresh may be up to this factor over baseline
TIME_TOL = 3.0
#: modeled quantities are deterministic — exact-ish only absorbs float repr
MODEL_RTOL = 1e-6


def _is_timing(key: str) -> bool:
    return key.startswith("us_")


def _is_throughput(key: str) -> bool:
    return key.startswith("tok_s_")


def _is_call_ratio(key: str) -> bool:
    return key.startswith("kernel_calls")


def _compare(path: str, base, fresh, errors: list[str]) -> None:
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            errors.append(f"{path}: baseline dict, fresh {type(fresh).__name__}")
            return
        for key in base:
            if key not in fresh:
                errors.append(f"{path}.{key}: missing from fresh run")
                continue
            _compare(f"{path}.{key}", base[key], fresh[key], errors)
        for key in fresh:
            if key not in base:
                errors.append(f"{path}.{key}: new row not in baseline — "
                              "regenerate BENCH_schedule.json")
        return
    if isinstance(base, list):
        if not isinstance(fresh, list) or len(base) != len(fresh):
            errors.append(f"{path}: length {len(base)} -> "
                          f"{len(fresh) if isinstance(fresh, list) else fresh}")
            return
        for i, (b, f) in enumerate(zip(base, fresh)):
            _compare(f"{path}[{i}]", b, f, errors)
        return
    key = path.rsplit(".", 1)[-1].split("[")[0]
    if isinstance(base, (int, float)) and isinstance(fresh, (int, float)) \
            and not isinstance(base, bool):
        if _is_timing(key):
            if base > 0 and fresh > TIME_TOL * base:
                errors.append(f"{path}: timing regressed "
                              f"{base:.1f}us -> {fresh:.1f}us "
                              f"(> {TIME_TOL}x)")
        elif _is_throughput(key):
            if base > 0 and fresh < base / TIME_TOL:
                errors.append(f"{path}: throughput regressed "
                              f"{base:.1f} -> {fresh:.1f} tok/s "
                              f"(< 1/{TIME_TOL}x)")
        elif _is_call_ratio(key):
            if base > 0 and fresh > TIME_TOL * base:
                errors.append(f"{path}: dispatch ratio regressed "
                              f"{base:.2f} -> {fresh:.2f} kernel "
                              f"calls/token (> {TIME_TOL}x)")
        elif not math.isclose(base, fresh, rel_tol=MODEL_RTOL,
                              abs_tol=1e-12):
            errors.append(f"{path}: modeled value drifted {base!r} -> "
                          f"{fresh!r}")
        return
    if base != fresh:
        errors.append(f"{path}: {base!r} -> {fresh!r}")


def _gate(json_name: str, module: str) -> int:
    import importlib
    path = os.path.join(_ROOT, json_name)
    if not os.path.exists(path):
        print(f"no committed {json_name} baseline — run "
              f"`PYTHONPATH=src python -m benchmarks.{module}` and "
              "commit it", file=sys.stderr)
        return 1
    with open(path) as f:
        baseline = json.load(f)

    importlib.import_module(f"benchmarks.{module}").run()  # rewrites json
    with open(path) as f:
        fresh = json.load(f)

    errors: list[str] = []
    _compare(module, baseline, fresh, errors)
    if errors:
        print(f"{json_name}: {len(errors)} violation(s) vs committed "
              "baseline", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    n_timings = sum(
        1 for section in baseline.values() if isinstance(section, (list, dict))
        for rec in (section if isinstance(section, list) else [section])
        if isinstance(rec, dict)
        for k in rec
        if _is_timing(k) or _is_throughput(k) or _is_call_ratio(k))
    print(f"{json_name} gate clean: modeled values exact, "
          f"{n_timings} timings within {TIME_TOL}x of baseline")
    return 0


def main() -> int:
    return max(_gate(name, mod) for name, mod in BASELINES)


if __name__ == "__main__":
    sys.exit(main())
