"""Kernel-level microbenchmarks: interpret-mode Pallas vs jnp oracle (CPU
correctness-path timing; real perf is the TPU target) + the unified-operator
dispatch overheads."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels import ops, ref


def run():
    rows = []
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (256, 256), jnp.float32)
    b = jax.random.normal(k2, (256, 256), jnp.float32)
    us_ref = time_fn(jax.jit(ref.gemm_ref), a, b)
    us_pal = time_fn(lambda: ops.moa_gemm(a, b, interpret=True),
                     warmup=1, iters=3)
    rows.append(("kernels/gemm_256/xla", us_ref, "oracle"))
    rows.append(("kernels/gemm_256/pallas_interpret", us_pal,
                 "correctness path (TPU is the perf target)"))
    for mode, shapes in [("hp", ((128, 128), (128, 128))),
                         ("op", ((16, 16), (16, 16))),
                         ("kp", ((16, 16), (16, 16)))]:
        x = jax.random.normal(k1, shapes[0], jnp.float32)
        y = jax.random.normal(k2, shapes[1], jnp.float32)
        us = time_fn(lambda: ops.ipophp(x, y, mode, interpret=True),
                     warmup=1, iters=3)
        rows.append((f"kernels/ipophp_{mode}", us, "unified circuit"))
    e = jax.random.normal(k1, (4, 128, 128), jnp.float32)
    w = jax.random.normal(k2, (4, 128, 64), jnp.float32)
    us = time_fn(lambda: ops.expert_gemm(e, w, interpret=True),
                 warmup=1, iters=3)
    rows.append(("kernels/expert_gemm_4x128", us, "lifted expert axis"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
