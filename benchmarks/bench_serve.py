"""Serving-engine benchmark: a seeded Poisson request trace through the
continuous-batching engine on two archs (gemma-2b paged / mamba2-780m
contiguous), reduced configs on this host (interpret-mode kernels on the
paged path).  Each row replays the trace twice against one engine and
measures the second pass, so every row reports warm steady-state
serving rather than whichever share of trace/compile cost the row
ordering happened to leave it.

Writes ``BENCH_serve.json``: per-arch throughput (``tok_s_*`` — gated
inverse-tolerant), p50/p99 request latency and time-to-first-token
(``us_*`` — gated 3x-tolerant), plus the deterministic quantities CI pins
exactly: trace/engine shape (page size, pool pages, eviction count, token
counts) and the modeled decode-step HBM bytes/token from ``core.energy``
at the cache capacity — the "Racing to Idle" ledger for the decode path,
mirroring what ``BENCH_schedule.json`` does for training kernels.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.blocking import RecurrenceBlockChoice, StreamBlockChoice
from repro.core.energy import attention_energy, scan_energy
from repro.models import registry
from repro.models.ssm import conv_dim, d_inner, n_ssd_heads
from repro.serving import ServeEngine

ARCHS = ("gemma-2b", "mamba2-780m")
#: seeded Poisson trace: exponential interarrivals at RATE req/s (virtual
#: time — arrival timestamps are data, the engine replays them against its
#: wall clock), prompt/new-token extents drawn per request.  10 requests
#: against <= 4 slots keeps the engine SATURATED for most of the replay —
#: the regime continuous batching exists for, and the one where the
#: batched launch's dispatch amortization is visible rather than washed
#: out by a drained-tail engine running one or two live slots.  The rate
#: puts every interarrival in the nanoseconds, so the whole burst is
#: queued before the engine's FIRST step and admission is purely
#: queue-driven — deterministic whatever the wall clock does, so the
#: warm measured pass re-traces nothing (a rate where arrivals straddle
#: step boundaries makes slab assignment, and hence the executor keys,
#: timing-dependent)
TRACE = dict(seed=0, n_requests=10, rate=1e9, prompt_lo=4,
             prompt_hi=12, new_lo=6, new_hi=12)
MAX_LEN = 64
PAGE = 8
MAX_SLOTS = 2
JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_serve.json")


def poisson_trace(vocab: int) -> list[dict]:
    """The seeded request trace: deterministic given TRACE."""
    rng = np.random.default_rng(TRACE["seed"])
    t = 0.0
    reqs = []
    for _ in range(TRACE["n_requests"]):
        t += float(rng.exponential(1.0 / TRACE["rate"]))
        s0 = int(rng.integers(TRACE["prompt_lo"], TRACE["prompt_hi"] + 1))
        n_new = int(rng.integers(TRACE["new_lo"], TRACE["new_hi"] + 1))
        prompt = rng.integers(0, vocab, s0).tolist()
        reqs.append(dict(arrival=t, prompt=prompt, max_new=n_new))
    return reqs


def _modeled_hbm_per_token(cfg) -> float:
    """Modeled decode-step HBM bytes per generated token at cache
    capacity — one engine decode step across all layers."""
    if cfg.family == "dense":
        g = cfg.n_heads // cfg.n_kv_heads
        blocks = StreamBlockChoice(g, PAGE, 0, 0.0, 1.0)
        rep = attention_energy(1, cfg.n_heads, 1, MAX_LEN, cfg.head_dim_,
                               blocks, dtype=cfg.dtype)
        return cfg.n_layers * rep.hbm_bytes
    if cfg.family == "ssm":
        h, p, n = n_ssd_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
        rep = scan_energy(1, 1, h, p, n,
                          RecurrenceBlockChoice(1, 0, 0.0, 1.0),
                          dtype=cfg.dtype)
        return cfg.n_layers * rep.hbm_bytes
    raise ValueError(cfg.family)


def _run_pass(engine, trace: list[dict]) -> dict:
    """Replay the trace against the engine once; metrics for THIS pass
    only (the engine keeps its jitted executables across passes)."""
    t0 = time.perf_counter()
    clock = lambda: time.perf_counter() - t0
    pending = list(trace)
    rids = []
    n_decoded = 0
    decode_t0 = None
    calls0 = engine.kernel_calls
    while pending or not engine.idle:
        now = clock()
        while pending and pending[0]["arrival"] <= now:
            req = pending.pop(0)
            rids.append(engine.submit(req["prompt"], req["max_new"],
                                      now=now))
        emitted = engine.step(now=clock())
        if emitted and decode_t0 is None:
            decode_t0 = clock()
        n_decoded += len(emitted)
        if not emitted and pending and engine.idle:
            # idle gap before the next arrival: jump the wall clock by
            # sleeping to the arrival (virtual rates are fast; this is ms)
            time.sleep(max(0.0, pending[0]["arrival"] - clock()))
    results = engine.results()
    return dict(rids=rids, n_decoded=n_decoded, wall=clock(),
                decode_t0=decode_t0,
                kernel_calls=engine.kernel_calls - calls0,
                requests=[results[r]["request"] for r in rids])


def _replay(cfg, params, trace: list[dict], max_slots: int = MAX_SLOTS,
            batched=None) -> dict:
    paged = cfg.family == "dense"
    engine = ServeEngine(cfg, params, max_slots=max_slots, max_len=MAX_LEN,
                         page=PAGE if paged else None,
                         interpret=True if paged else None,
                         batched=batched)
    # warm-up replay: pays every trace/compile once so the measured pass
    # is warm steady-state serving for EVERY row — without it, a row
    # inherits whichever executors earlier rows happened to share (the
    # module-level kernel caches are keyed on shapes + tables) and the
    # comparison across rows is cold-start lottery, not serving rate
    _run_pass(engine, trace)
    p = _run_pass(engine, trace)
    n_decoded, wall, decode_t0 = p["n_decoded"], p["wall"], p["decode_t0"]
    lat = sorted(r.done_t - r.submit_t for r in p["requests"])
    ttft = sorted(r.first_tok_t - r.submit_t for r in p["requests"])
    pct = lambda xs, p: float(np.percentile(xs, p))
    return {
        "arch": cfg.name,
        "paged": engine.paged,
        "batched": engine.batched,
        "page": engine.page,
        "pool_pages": engine.pool.pool_pages if engine.pool else 0,
        "max_slots": engine.max_slots,
        "n_requests": len(trace),
        "n_tokens": n_decoded,
        "evictions": sum(r.evictions for r in p["requests"]),
        "kernel_calls_per_token": p["kernel_calls"] / max(n_decoded, 1),
        "tok_s_decode": n_decoded / max(wall - (decode_t0 or 0.0), 1e-9),
        "us_p50_latency": pct(lat, 50) * 1e6,
        "us_p99_latency": pct(lat, 99) * 1e6,
        "us_p50_ttft": pct(ttft, 50) * 1e6,
        "us_p99_ttft": pct(ttft, 99) * 1e6,
        "modeled_hbm_bytes_per_token": _modeled_hbm_per_token(cfg),
    }


#: (arch, max_slots, batched) per row: the legacy 2-slot rows, plus the
#: per-slot vs batched pair at 4 slots — the dispatch-amortization claim
#: the batched slot lift makes, benched side by side
ROWS = (("gemma-2b", MAX_SLOTS, False),
        ("gemma-2b", 4, False),
        ("gemma-2b", 4, True),
        ("mamba2-780m", MAX_SLOTS, None))


def run() -> dict:
    out = {"trace": dict(TRACE), "max_len": MAX_LEN,
           "max_slots": MAX_SLOTS, "rows": []}
    for arch, max_slots, batched in ROWS:
        cfg = get_config(arch, reduced=True)
        params, _ = registry.init(cfg, jax.random.PRNGKey(0))
        row = _replay(cfg, params, poisson_trace(cfg.vocab_size),
                      max_slots=max_slots, batched=batched)
        out["rows"].append(row)
        print(f"{arch} slots={max_slots} batched={row['batched']}: "
              f"{row['n_tokens']} tok, "
              f"{row['tok_s_decode']:.1f} tok/s, "
              f"{row['kernel_calls_per_token']:.2f} kernel calls/tok, "
              f"p50 {row['us_p50_latency'] / 1e3:.1f}ms "
              f"p99 {row['us_p99_latency'] / 1e3:.1f}ms, "
              f"{row['modeled_hbm_bytes_per_token'] / 1e6:.2f} modeled "
              f"MB/token")
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {os.path.normpath(JSON_PATH)}")
    return out


if __name__ == "__main__":
    run()
