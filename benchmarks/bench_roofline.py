"""Paper Table 1 analogue + §Roofline: the hardware hierarchy table and the
per-(arch x shape x mesh) roofline terms read from the dry-run artifacts
(results/dryrun/*.json).  Run the dry-run first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.lifting import TPU_V5E

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun_final")


def hardware_rows():
    hw = TPU_V5E
    return [
        ("roofline/hw/peak_bf16", "-", f"{hw.peak_flops / 1e12:.0f}TFLOPs"),
        ("roofline/hw/hbm", "-", f"{hw.hbm.bandwidth_Bps / 1e9:.0f}GB/s "
         f"{hw.hbm.capacity_bytes / 2**30:.0f}GiB"),
        ("roofline/hw/ici", "-", f"{hw.ici_Bps / 1e9:.0f}GB/s/link"),
        ("roofline/hw/vmem_budget", "-", f"{hw.vmem.capacity_bytes / 2**20:.0f}MiB"),
        ("roofline/hw/mesh", "-", "(16 data x 16 model) x 2 pods"),
    ]


def run():
    rows = hardware_rows()
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        rows.append(("roofline/cells", "-", "NO DRYRUN ARTIFACTS — run dryrun"))
        return rows
    n_ok = n_skip = n_fail = 0
    for f in files:
        rec = json.load(open(f))
        tag = f"roofline/{rec['arch']}/{rec['shape']}/{rec.get('mesh', '?')}"
        if rec.get("status") == "SKIP":
            n_skip += 1
            rows.append((tag, "-", "SKIP " + rec.get("reason", "")[:60]))
            continue
        if rec.get("status") != "OK":
            n_fail += 1
            rows.append((tag, "-", "FAIL " + rec.get("error", "")[:80]))
            continue
        n_ok += 1
        rl = rec["roofline"]
        rows.append((tag, "-",
                     f"compute_s={rl['compute_s']:.3e} "
                     f"memory_s={rl['memory_s']:.3e} "
                     f"collective_s={rl['collective_s']:.3e} "
                     f"dominant={rl['dominant']} "
                     f"useful={rl['useful_flops_ratio']:.2f} "
                     f"frac={rl['roofline_fraction']:.3f}"))
    rows.append(("roofline/summary", "-",
                 f"ok={n_ok} skip={n_skip} fail={n_fail}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
