"""Shared benchmark utilities: timing + CSV row conventions.

Every bench module exposes ``run() -> list[(name, us_per_call, derived)]``.
``us_per_call`` is measured wall time on THIS host (CPU) — "-" when a row is
model-only; ``derived`` is the analytic quantity the row exists for
(modeled TPU time/energy, roofline terms, block choices, ...).
"""
from __future__ import annotations

import time
from typing import Callable

Row = tuple  # (name, us_per_call, derived)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time in microseconds (results blocked on)."""
    import jax
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or True else None
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        try:
            jax.block_until_ready(r)
        except Exception:
            pass
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(rows: list[Row]) -> None:
    for name, us, derived in rows:
        us_s = f"{us:.1f}" if isinstance(us, (int, float)) else str(us)
        print(f"{name},{us_s},{derived}")
