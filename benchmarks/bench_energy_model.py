"""Paper figs 9-11 + §3.6.3: power/heat vs time/energy relationships.

Derived-only (no watts on CPU): reproduces the paper's observation that
power varies ~10% while time varies ~380x, i.e. energy curves are shaped by
time, and the power/"temperature" (power-density proxy) ordering is the
INVERSE of the time/energy ordering across block sizes.
"""
from __future__ import annotations

import numpy as np

from repro.core import energy

BLOCKS = [64, 128, 256, 512, 1024]


def run():
    rows = []
    times, powers = [], []
    for n in [4096, 8192, 16384]:
        res = energy.energy_vs_blocksize(n, BLOCKS)
        for b, rep in res:
            # temperature proxy: power density over the active block area
            temp = rep.power_W / (3 * b * b * 2 / 2**20)   # W per MiB working set
            rows.append((f"energy_model/N{n}/b{b}", "-",
                         f"power_W={rep.power_W:.0f} temp_proxy={temp:.1f} "
                         f"time_s={rep.time_s:.3e} energy_J={rep.energy_J:.2f}"))
            times.append(rep.time_s)
            powers.append(rep.power_W)
    t_ratio = max(times) / min(times)
    p_ratio = max(powers) / min(powers)
    rows.append(("energy_model/sec3.6.3_ratios", "-",
                 f"time_maxmin={t_ratio:.1f}x power_maxmin={p_ratio:.2f}x "
                 f"(paper: 378x vs 1.115x)"))
    # inverse correlation check: best-time block has higher power than worst
    res = dict(energy.energy_vs_blocksize(8192, BLOCKS))
    bt = min(res, key=lambda b: res[b].time_s)
    wt = max(res, key=lambda b: res[b].time_s)
    rows.append(("energy_model/inverse_power_time", "-",
                 f"best_time_block={bt} P={res[bt].power_W:.0f}W "
                 f"worst_time_block={wt} P={res[wt].power_W:.0f}W "
                 f"inverse={'yes' if res[bt].power_W > res[wt].power_W else 'no'}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
